"""Process fabric: real RPC, real signals, kill-tested preemption.

The headline test SIGKILLs a worker process mid-job; a replacement process
restores from the last *committed* published CMI and the final product is
bit-identical to an uninterrupted run. A SIGTERM variant exercises the
2-minute-notice path (publish, then exit EXIT_PREEMPTED).

Every test is wrapped in a SIGALRM guard (pytest-timeout is not in the
image) so a hung worker can never wedge the suite.
"""

import os
import signal
import time

import numpy as np
import pytest

from repro.core import NBS, DHP
from repro.core.cmi import restore_cmi
from repro.core.jobstore import JobStore, STATUS_CKPT, STATUS_FINISHED
from repro.core.preemption import SpotSchedule
from repro.fabric import wire
from repro.fabric.proxy import RemoteStateRef
from repro.fabric.supervisor import FabricSupervisor
from repro.fabric.worker import EXIT_FINISHED, EXIT_NO_JOB, EXIT_PREEMPTED

PER_TEST_TIMEOUT_S = int(os.environ.get("NAVP_TEST_TIMEOUT", "180"))


@pytest.fixture(autouse=True)
def _alarm_guard():
    """Per-test wall-clock guard: process-spawning tests must never hang."""

    def on_alarm(signum, frame):
        raise TimeoutError(f"fabric test exceeded {PER_TEST_TIMEOUT_S}s")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(PER_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture
def fab(tmp_path, request):
    """(supervisor, jobstore) with guaranteed worker cleanup.

    Indirect-parametrize with "unix" or "tcp" to pick the worker transport;
    unparametrized tests use unix sockets (the fast local default)."""
    transport = getattr(request, "param", "unix")
    jroot = tmp_path / "jobs"
    sup = FabricSupervisor(str(tmp_path / "s3"), str(jroot), transport=transport)
    try:
        yield sup, JobStore(jroot)
    finally:
        sup.shutdown()


both_transports = pytest.mark.parametrize("fab", ["unix", "tcp"], indirect=True)


def _product_bytes(js: JobStore, job_id: str) -> bytes:
    job = js.read_job(job_id)
    assert job.status == STATUS_FINISHED and job.product
    state, _ = restore_cmi(js.cmi_root(job_id), job.product)
    return state["w"].tobytes() + str(state["t"]).encode()


# ---------------------------------------------------------------------------
# wire
# ---------------------------------------------------------------------------


def test_wire_roundtrip_both_codecs():
    msgs = [
        {"svc": "svc/hop", "kwargs": {"cmi": "hop-abc", "io_threads": 4}},
        {"blob": b"\x00\xffbytes", "nested": [1, 2.5, None, "x"]},
    ]
    for prefer in (True, False):
        for msg in msgs:
            framed = wire.encode(msg, prefer_msgpack=prefer)
            body = framed[4:]
            assert wire.decode_body(body[:1], body[1:]) == msg


def test_wire_rejects_bad_frames():
    with pytest.raises(wire.WireError):
        wire.decode_body(b"Z", b"{}")


def test_tcp_connect_timeout_bounds_unanswered_syn():
    """S1 regression: without the per-attempt connect timeout, a SYN that is
    never answered sits in the kernel's retry cycle for minutes. A listener
    with a saturated accept backlog drops further SYNs — the local stand-in
    for a blackholed route (this container's egress proxy answers every
    external address, so a non-routable IP can't model it)."""
    import socket as pysocket

    srv = pysocket.socket()
    fillers = []
    try:
        srv.bind(("127.0.0.1", 0))
        srv.listen(0)
        for _ in range(2):  # saturate the accept queue; never accept
            f = pysocket.socket()
            f.settimeout(0.3)
            try:
                f.connect(srv.getsockname())
            except OSError:
                pass
            fillers.append(f)
        t0 = time.monotonic()
        with pytest.raises(OSError):
            wire.connect(("tcp", "127.0.0.1", srv.getsockname()[1]), timeout=0.3)
        assert time.monotonic() - t0 < 3.0
    finally:
        for f in fillers:
            f.close()
        srv.close()


def test_tcp_connect_retries_are_bounded_by_backoff():
    """attempts>1 retries under bounded exponential backoff + jitter; the
    total walltime stays attempts*timeout + sum(backoffs), not unbounded."""
    # a port that refuses instantly: bind-then-close frees it
    import socket as pysocket

    with pysocket.socket() as probe:
        probe.bind(("127.0.0.1", 0))
        dead_port = probe.getsockname()[1]
    t0 = time.monotonic()
    with pytest.raises(OSError):
        wire.connect(("tcp", "127.0.0.1", dead_port),
                     timeout=0.2, attempts=3, backoff_s=0.05)
    assert time.monotonic() - t0 < 3.0


def test_tcp_socket_options_on_both_client_and_server_sides(tmp_path):
    """S2: TCP_NODELAY (latency: control frames must not Nagle-coalesce) and
    SO_KEEPALIVE (dead-peer detection on idle fleet links) are set at socket
    creation on the CLIENT socket and on the server's ACCEPTED socket —
    accepted sockets do not reliably inherit listener options."""
    import socket as pysocket

    from repro.fabric.proxy import FabricClient
    from repro.fabric.server import NodeServer

    nbs = NBS(tmp_path / "s3")
    nbs.add_node("B", mesh=None)
    server = NodeServer(nbs, "B", ("tcp", "127.0.0.1", 0)).start()
    try:
        c = FabricClient(server.address)
        assert c.request("svc/ping")["node"] == "B"  # accept happened
        for sock, side in ((c._sock, "client"), (server._last_accepted, "server")):
            assert sock is not None, side
            assert sock.getsockopt(pysocket.IPPROTO_TCP, pysocket.TCP_NODELAY), side
            assert sock.getsockopt(pysocket.SOL_SOCKET, pysocket.SO_KEEPALIVE), side
        c.close()
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# RPC: RemoteNode proxy over a live worker process
# ---------------------------------------------------------------------------


def test_remote_node_rpc_ping_hop_fetch(fab, tmp_path):
    sup, _ = fab
    handle = sup.spawn("B", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("B", handle.address)

    info = nbs.call("B", "svc/ping")
    assert info["node"] == "B" and info["pid"] == handle.pid
    assert info["pid"] != os.getpid()  # genuinely another process

    # unknown service surfaces as RemoteError with the remote traceback
    with pytest.raises(wire.RemoteError, match="no service"):
        nbs.call("B", "svc/nope")

    # store-mediated hop: state lands in the worker; receipt comes back
    dhp = DHP(nbs, "A")
    src = {"x": np.arange(64, dtype=np.float64), "step": 7}
    ref = dhp.hop(dict(src), "B", via="store")
    assert isinstance(ref, RemoteStateRef) and ref.leaves == 2 and ref.step == 7
    assert dhp.node == "B"

    # the transit hop-CMI was GC'd inside the worker after restore
    fetched = nbs.call("B", "svc/fetch", token=ref.token)
    names = {p.name for p in nbs.hop_root.iterdir()}
    assert fetched["cmi"] in names and len(names) == 1

    back, _ = restore_cmi(nbs.hop_root, fetched["cmi"])
    assert back["x"].tobytes() == src["x"].tobytes()
    assert int(back["step"]) == 7

    nbs.remove_node("B")  # closes the client socket
    # serve-only workers must still honor the SIGTERM notice path
    assert sup.reclaim("B", notice=True) == EXIT_PREEMPTED


def test_hop_retry_after_connection_kill_dedups(tmp_path):
    """svc/hop is in _RETRY_SAFE, but the server GCs the transit CMI after
    restoring it: a reconnect-resend after the server already executed must
    converge on the ORIGINAL receipt (server-side dedup keyed on the CMI
    name), not fail on the missing CMI."""
    from repro.core.cmi import save_cmi
    from repro.fabric.proxy import FabricClient
    from repro.fabric.server import NodeServer

    nbs = NBS(tmp_path / "s3")
    nbs.add_node("B", mesh=None)
    save_cmi(nbs.hop_root, "hop-dup", {"x": np.arange(32, dtype=np.float64)}, step=3)
    server = NodeServer(nbs, "B", ("tcp", "127.0.0.1", 0)).start()
    try:
        c = FabricClient(server.address, reconnect_timeout_s=5.0)
        # send the request, let the server execute it, then kill the
        # connection BEFORE reading the response — exactly the window where
        # the transit CMI is already gone
        wire.send_msg(c._sock, {"id": 1, "svc": "svc/hop", "kwargs": {"cmi": "hop-dup"}})
        deadline = time.monotonic() + 10
        while not server.resident:
            assert time.monotonic() < deadline, "server never executed svc/hop"
            time.sleep(0.01)
        assert not (nbs.hop_root / "hop-dup").exists()  # transit CMI GC'd
        c._sock.close()  # the response is lost with the connection
        receipt = c.request("svc/hop", cmi="hop-dup")  # reconnect-resend
        assert receipt["token"] in server.resident
        assert len(server.resident) == 1  # executed once, not twice
        c.close()
    finally:
        server.stop()


def test_claim_next_get_job_is_not_resent(fab, tmp_path):
    """svc/get_job without a job_id is claim-NEXT: a reconnect-resend after
    the server already leased a job would lease a SECOND one and strand the
    first. The client must surface the transport error instead."""
    from repro.fabric.proxy import FabricClient
    from repro.fabric.server import NodeServer

    sup, js = fab
    j1 = js.create_job({"seed": 1})
    js.create_job({"seed": 2})
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("B", mesh=None)
    server = NodeServer(nbs, "B", ("tcp", "127.0.0.1", 0), jobstore=js).start()
    try:
        c = FabricClient(server.address, reconnect_timeout_s=5.0)
        # named-job form stays retry-safe: re-leasing converges
        wire.send_msg(c._sock, {"id": 1, "svc": "svc/get_job",
                                "kwargs": {"job_id": j1.job_id, "worker": "w0"}})
        deadline = time.monotonic() + 10
        while js.read_job(j1.job_id).lease_owner != "w0":
            assert time.monotonic() < deadline
            time.sleep(0.01)
        c._sock.close()
        got = c.request("svc/get_job", job_id=j1.job_id, worker="w0")
        assert got["job_id"] == j1.job_id and got["lease_owner"] == "w0"

        # claim-next form: the lost-response resend must raise, not lease
        # another job on top of the one this worker (unknowingly) holds
        c._sock.close()
        with pytest.raises((OSError, wire.WireError)):
            c.request("svc/get_job", worker="w0")
        leased = [jid for jid, _ in js.svc_list_jobs()
                  if js.read_job(jid).lease_owner == "w0"]
        assert leased == [j1.job_id]  # no second job was claimed
        c.close()
    finally:
        server.stop()


def test_remote_jobstore_services(fab):
    sup, js = fab
    job = js.create_job({"seed": 1})
    handle = sup.spawn("B", serve_only=True)
    nbs = NBS(sup.store_root)
    nbs.add_remote_node("B", handle.address)
    assert nbs.call("B", "svc/list_jobs") == [[job.job_id, "new"]]
    got = nbs.call("B", "svc/get_job", job_id=job.job_id, worker="tester")
    assert got["job_id"] == job.job_id and got["lease_owner"] == "tester"
    # leased now -> a claim-next from another caller finds nothing
    assert nbs.call("B", "svc/get_job", worker="rival") is None


# ---------------------------------------------------------------------------
# kill-tested preemption (the acceptance test)
# ---------------------------------------------------------------------------

JOB_INPUT = {"seed": 3, "n": 1024, "steps": 40, "publish_every": 5}


def _run_clean(sup: FabricSupervisor, js: JobStore) -> bytes:
    job = js.create_job(JOB_INPUT)
    out = sup.run_job(job.job_id, steps=40, publish_every=5, step_ms=1, timeout_s=120)
    assert out["incarnations"] == 1 and out["reclaims"] == 0
    return _product_bytes(js, job.job_id)


@both_transports
def test_sigkill_mid_job_resumes_bit_identical(fab, tmp_path):
    """SIGKILL (no notice) mid-job; a fresh process resumes from the last
    published CMI; the product is bit-identical to an uninterrupted run."""
    sup, js = fab
    clean = _run_clean(sup, js)

    job = js.create_job(JOB_INPUT)
    sched = SpotSchedule(preempt_steps=(10,), max_preemptions=1)
    out = sup.run_job(
        job.job_id, schedule=sched, notice=False,
        steps=40, publish_every=5, step_ms=20, timeout_s=300,
    )
    assert out["reclaims"] == 1 and out["incarnations"] == 2
    assert _product_bytes(js, job.job_id) == clean


def test_sigterm_notice_publishes_then_resumes_bit_identical(fab):
    """The 2-minute-notice path: SIGTERM -> worker publishes a CMI, exits
    EXIT_PREEMPTED; replacement resumes to a bit-identical product."""
    sup, js = fab
    clean = _run_clean(sup, js)

    job = js.create_job(JOB_INPUT)
    name = "victim-0"
    sup.spawn(name, job_id=job.job_id, steps=40, publish_every=5,
              step_ms=25, grace_s=30)
    # wait for the worker to get past its first published checkpoint
    # (svc_publish_job sets status and cmi atomically under the job lock)
    j = js.wait_for_status(job.job_id, STATUS_CKPT, timeout_s=60)
    assert j.cmi is not None

    rc = sup.reclaim(name, notice=True)
    assert rc == EXIT_PREEMPTED
    j = js.read_job(job.job_id)
    assert j.status == STATUS_CKPT and j.cmi is not None

    sup.spawn("victim-1", job_id=job.job_id, steps=40, publish_every=5, step_ms=1)
    assert sup.workers["victim-1"].wait(timeout=60) == EXIT_FINISHED
    assert _product_bytes(js, job.job_id) == clean


def test_concurrent_claimants_one_winner(fab):
    """The jobstore's fcntl leases under genuinely concurrent processes:
    exactly one claimant wins the job; the others exit EXIT_NO_JOB."""
    sup, js = fab
    job = js.create_job({"seed": 5, "n": 256, "steps": 150, "publish_every": 25})
    # wait=False: the claimants race for the lease from the moment they
    # start, and a loser may exit before it can ever be pinged
    handles = [
        sup.spawn(f"claimant-{i}", claim=True, steps=150, publish_every=25,
                  step_ms=30, lease_s=300, wait=False)
        for i in range(3)
    ]
    rcs = sorted(h.wait(timeout=120) for h in handles)
    assert rcs == [EXIT_FINISHED, EXIT_NO_JOB, EXIT_NO_JOB]
    assert js.read_job(job.job_id).status == STATUS_FINISHED


def test_supervisor_respawns_on_crash(fab):
    """A worker that dies without any schedule (rogue kill -9 from outside
    the supervisor's reclaim path) is detected and replaced."""
    sup, js = fab
    job = js.create_job(JOB_INPUT)
    import threading

    def assassin():
        # wait for the first checkpoint, then murder whatever worker exists
        js.wait_for_status(job.job_id, STATUS_CKPT, timeout_s=60)
        if sup.workers:
            h = next(iter(sup.workers.values()))
            try:
                os.kill(h.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    t = threading.Thread(target=assassin, daemon=True)
    t.start()
    out = sup.run_job(job.job_id, steps=40, publish_every=5, step_ms=20, timeout_s=300)
    t.join(timeout=10)
    assert out["incarnations"] >= 2
    assert js.read_job(job.job_id).status == STATUS_FINISHED


# ---------------------------------------------------------------------------
# streaming hops (svc/hop_stream): disk-bypassing transport + fallback
# ---------------------------------------------------------------------------


def _fetch_state(nbs, token):
    fetched = nbs.call("W", "svc/fetch", token=token, drop=False)
    state, _ = restore_cmi(nbs.hop_root, fetched["cmi"])
    return state


def test_stream_hop_bypasses_disk_bit_identical(fab, tmp_path):
    """via="auto" against a process-backed node streams: no hop-CMI ever
    touches the store, and the fetched state is bit-identical."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(1).standard_normal((500, 64)), "step": 9}
    ref = dhp.hop(dict(src), "W")
    assert isinstance(ref, RemoteStateRef) and ref.via == "stream"
    assert ref.step == 9 and dhp.node == "W"
    # the whole point: nothing transited the shared store
    assert list(nbs.hop_root.iterdir()) == []

    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes() and back["step"] == 9


def test_stream_delta_second_hop_sends_only_changed_chunks(fab, tmp_path):
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)  # 16 KiB chunks

    src = {"x": np.random.default_rng(2).standard_normal((1000, 64))}
    dhp.hop(dict(src), "W")
    full = dict(wnode.last_stream_receipt)
    assert full["ref_chunks"] == 0

    # mutate ~10% of the rows; the repeat hop deltas against the resident
    src2 = {"x": src["x"].copy()}
    src2["x"][:100] += 1.0
    ref2 = dhp.hop(dict(src2), "W")
    delta = dict(wnode.last_stream_receipt)
    assert ref2.via == "stream"
    assert delta["ref_chunks"] > 0 and delta["data_chunks"] < full["data_chunks"] / 2
    assert delta["sent_bytes"] < full["sent_bytes"] / 2

    back = _fetch_state(nbs, ref2.token)
    assert back["x"].tobytes() == src2["x"].tobytes()


def test_stream_failure_falls_back_to_store_transparently(fab, tmp_path):
    """Receiver aborts mid-stream (fault injection, as a dying receiver
    would): dhp.hop transparently retries via the store-mediated path and
    the state still lands bit-identical."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    wnode._stream_fail_after = 2  # receiver dies after 2 chunks, every time
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(3).standard_normal((500, 64)), "step": 4}
    ref = dhp.hop(dict(src), "W")  # via=auto -> stream -> fallback
    assert isinstance(ref, RemoteStateRef) and ref.via == "store"
    assert ref.step == 4
    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes()
    # nothing half-streamed became resident: only the store-hop state lives
    assert nbs.call("W", "svc/ping")["resident"] == 1


@both_transports
def test_stream_midkill_falls_back_to_respawned_worker(fab, tmp_path):
    """SIGKILL the destination worker mid-stream. The sender's stream fails;
    a replacement worker comes up at the SAME address (respawn-in-place —
    a pinned unix path or a pinned tcp port); the transparent store-mediated
    fallback reconnects and completes, and the state is bit-identical."""
    import threading

    sup, _ = fab
    sock_path = sup.pin("W")
    handle = sup.spawn("W", serve_only=True, socket_path=sock_path)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("W", handle.address)
    # ~256 chunks of 16 KiB with a 20 ms pause between sends: a multi-second
    # kill window no scheduler hiccup can miss
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)
    src = {"x": np.random.default_rng(4).standard_normal((4096, 64)), "step": 8}

    killed = threading.Event()

    def assassin():
        time.sleep(0.5)  # stream setup + first chunks are long gone by now
        sup.reclaim("W", notice=False)  # SIGKILL, no notice
        sup.spawn("W", serve_only=True, socket_path=sock_path)
        killed.set()

    t = threading.Thread(target=assassin, daemon=True)
    t.start()
    os.environ["REPRO_STREAM_CHUNK_PAUSE_S"] = "0.02"
    try:
        ref = dhp.hop(dict(src), "W")
    finally:
        os.environ.pop("REPRO_STREAM_CHUNK_PAUSE_S", None)
        t.join(timeout=30)
    assert killed.is_set(), "worker was never killed mid-stream"
    assert isinstance(ref, RemoteStateRef) and ref.via == "store"
    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes() and back["step"] == 8


def test_stream_baseline_invalidated_on_fallback(fab, tmp_path):
    """Regression: after a stream hop failed and fell back to the store
    path, RemoteNode kept its delta baseline + receipt — the next hop could
    negotiate against state the receiver no longer holds (and benches would
    read a stale receipt). Both must be dropped on failure; the next stream
    hop goes out full."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(6).standard_normal((500, 64))}
    dhp.hop(dict(src), "W")  # stream #1: baseline cached
    assert wnode._stream_baseline is not None

    wnode._stream_fail_after = 2  # receiver aborts: stream -> store fallback
    ref2 = dhp.hop(dict(src), "W")
    assert ref2.via == "store"
    assert wnode._stream_baseline is None and wnode.last_stream_receipt is None

    wnode._stream_fail_after = None
    src3 = {"x": src["x"].copy()}
    src3["x"][:10] += 1.0
    ref3 = dhp.hop(dict(src3), "W")  # must stream FULL, no stale delta
    assert ref3.via == "stream"
    assert wnode.last_stream_receipt["ref_chunks"] == 0
    back = _fetch_state(nbs, ref3.token)
    assert back["x"].tobytes() == src3["x"].tobytes()


def test_stream_baseline_invalidated_on_respawn_reconnect(fab, tmp_path):
    """Regression: a client reconnect to a worker respawned at the same
    address kept the old delta baseline, pointing at resident state the new
    incarnation never had. _reconnect must invalidate it."""
    sup, _ = fab
    sock_path = os.path.join(sup.socket_dir, "W-re.sock")
    handle = sup.spawn("W", serve_only=True, socket_path=sock_path)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(7).standard_normal((500, 64))}
    dhp.hop(dict(src), "W")
    assert wnode._stream_baseline is not None

    sup.reclaim("W", notice=False)  # SIGKILL: resident cache dies with it
    sup.spawn("W", serve_only=True, socket_path=sock_path)
    # first control request reconnects transparently — and must invalidate
    assert nbs.call("W", "svc/ping")["resident"] == 0
    assert wnode._stream_baseline is None and wnode.last_stream_receipt is None

    ref = dhp.hop(dict(src), "W")  # fresh full stream against the new worker
    assert ref.via == "stream"
    assert wnode.last_stream_receipt["ref_chunks"] == 0
    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes()


# ---------------------------------------------------------------------------
# remote itineraries: store-free tours across process-backed nodes
# ---------------------------------------------------------------------------


def _tour_stages(publish=False):
    from repro.core.itinerary import Stage
    from repro.fabric import worker as fw

    return [
        Stage("B", fw.tour_read, "read", publish=publish),
        Stage("C", fw.tour_compute, "compute", publish=publish),
        Stage("D", fw.tour_write, "write"),
    ]


def _tour_expected(x):
    from repro.fabric import worker as fw

    return fw.tour_write(fw.tour_compute(fw.tour_read({"x": x.copy()})))


def _tour_cluster(sup, tmp_path, names=("B", "C", "D"), socket_paths=None):
    for name in names:
        sup.spawn(name, serve_only=True,
                  socket_path=(socket_paths or {}).get(name))
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    for name in names:
        nbs.add_remote_node(name, sup.workers[name].address)
    return nbs


@both_transports
def test_remote_itinerary_store_free_tour(fab, tmp_path):
    """Fig. 8 across three real worker processes: the first hop streams, the
    node-to-node moves are worker-initiated relays, the stages run inside
    the workers, and the product streams back — the store's hop namespace
    stays empty for the whole tour."""
    from repro.core.itinerary import Itinerary

    sup, _ = fab
    nbs = _tour_cluster(sup, tmp_path)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)
    vias = []
    nbs.plugins.subscribe("on_hop", lambda **kw: vias.append(kw["via"]))

    x = np.random.default_rng(21).standard_normal((256, 64))
    it = Itinerary(dhp)
    out = it.run({"x": x.copy()}, _tour_stages())

    assert list(nbs.hop_root.iterdir()) == []  # store-free, the whole way
    # every leg streamed: no hop/relay fallback, no fetch_store return leg
    assert not any("store" in v for v in vias), vias
    expected = _tour_expected(x)
    assert np.asarray(out["x"]).tobytes() == expected["x"].tobytes()
    assert out["toured"] == 1
    assert [n for n, _ in it.trace] == ["read", "compute", "write"]
    for name in ("B", "C", "D"):  # every leg dropped its source copy
        assert nbs.call(name, "svc/ping")["resident"] == 0


def test_remote_itinerary_lambda_stage_localizes(fab, tmp_path):
    """A stage fn the worker cannot import (lambda) no longer raises
    NotImplementedError: the state streams back and the stage runs in the
    driver, completing the tour with the right answer."""
    from repro.core.itinerary import Itinerary, Stage
    from repro.fabric import worker as fw

    sup, _ = fab
    nbs = _tour_cluster(sup, tmp_path, names=("B",))
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)
    x = np.random.default_rng(22).standard_normal((128, 64))
    stages = [
        Stage("B", fw.tour_read, "read"),
        # a named fn whose reference the WORKER cannot import: the server's
        # StageResolutionError must degrade to driver-side execution
        Stage("B", fw.tour_write, "write", fn_ref="no.such.module:tour_write"),
        Stage("B", lambda s: {**s, "x": s["x"] * 2.0}, "double"),
    ]
    out = Itinerary(dhp).run({"x": x.copy()}, stages)
    expected = fw.tour_write(fw.tour_read({"x": x.copy()}))
    expected = {**expected, "x": expected["x"] * 2.0}
    assert np.asarray(out["x"]).tobytes() == expected["x"].tobytes()
    assert out["toured"] == 1
    assert list(nbs.hop_root.iterdir()) == []


def test_streamed_fetch_returns_state_without_store(fab, tmp_path):
    """dhp.fetch streams a resident state back over the fabric socket (the
    resident copy is dropped only after the ack); via="store" still works
    and GCs its transit CMI."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(9).standard_normal((500, 64)), "step": 5}
    ref = dhp.hop(dict(src), "W")
    assert ref.via == "stream"
    state = dhp.fetch(ref)
    assert state["x"].tobytes() == src["x"].tobytes() and int(state["step"]) == 5
    assert list(nbs.hop_root.iterdir()) == []  # no store in the path
    assert nbs.call("W", "svc/ping")["resident"] == 0  # dropped after the ack

    ref2 = dhp.hop(dict(src), "W")
    state2 = dhp.fetch(ref2, via="store")
    assert state2["x"].tobytes() == src["x"].tobytes()
    assert list(nbs.hop_root.iterdir()) == []  # transit CMI GC'd after restore
    assert nbs.call("W", "svc/ping")["resident"] == 0


def test_remote_tour_relay_failure_falls_back_per_hop(fab, tmp_path):
    """Fault injection: every stream INTO node C aborts, so the B->C relay
    fails — the runner must complete the tour via the per-hop store path and
    leave no transit CMI behind."""
    from repro.core.itinerary import Itinerary

    sup, _ = fab
    nbs = _tour_cluster(sup, tmp_path)
    nbs.node("C")._stream_fail_after = 1  # receiver C dies mid-stream, every time
    vias = []
    nbs.plugins.subscribe("on_hop", lambda **kw: vias.append(kw["via"]))
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    x = np.random.default_rng(23).standard_normal((256, 64))
    out = Itinerary(dhp).run({"x": x.copy()}, _tour_stages())

    assert "store" in vias  # the B->C leg store-fell-back
    expected = _tour_expected(x)
    assert np.asarray(out["x"]).tobytes() == expected["x"].tobytes()
    assert list(nbs.hop_root.iterdir()) == []  # fallback GC'd its transit CMI
    for name in ("B", "C", "D"):
        assert nbs.call(name, "svc/ping")["resident"] == 0


@both_transports
def test_remote_tour_midkill_resume_bit_identical(fab, tmp_path):
    """The tentpole acceptance: SIGKILL a worker mid-tour, respawn it in
    place, resume from the last published stage — the final product is
    bit-identical to an uninterrupted tour."""
    from repro.core.itinerary import Itinerary

    sup, js = fab
    socket_paths = {n: sup.pin(n) for n in ("B", "C", "D")}
    nbs = _tour_cluster(sup, tmp_path, socket_paths=socket_paths)
    x = np.random.default_rng(31).standard_normal((256, 64))
    stages = _tour_stages(publish=True)

    job_clean = js.create_job({})
    out_clean = Itinerary(DHP(nbs, "A", js, chunk_bytes=1 << 14),
                          job_clean.job_id).run({"x": x.copy()}, stages)

    # interrupted tour: C is dead when the tour tries to move there, so the
    # relay fails AND the per-hop store fallback cannot restore on C either
    job = js.create_job({})
    sup.reclaim("C", notice=False)
    nbs.node("C").client.reconnect_timeout_s = 1.0  # fail fast, not after 10s
    dhp = DHP(nbs, "A", js, chunk_bytes=1 << 14)
    with pytest.raises(OSError):
        Itinerary(dhp, job.job_id).run({"x": x.copy()}, stages)
    j = js.read_job(job.job_id)
    assert j.status == STATUS_CKPT  # stage "read" was published before the kill
    # the failed fallback must NOT have destroyed the holder's copy: B keeps
    # its resident state when the destination restore could not be confirmed
    assert nbs.call("B", "svc/ping")["resident"] >= 1

    # supervisor respawns C in place; a fresh driver resumes the tour
    sup.spawn("C", serve_only=True, socket_path=socket_paths["C"])
    nbs.call("C", "svc/ping")  # reconnect the proxy to the new incarnation
    it2 = Itinerary(DHP(nbs, "A", js, chunk_bytes=1 << 14), job.job_id)
    out2 = it2.resume(stages)
    assert [n for n, _ in it2.trace] == ["compute", "write"]
    assert np.asarray(out2["x"]).tobytes() == np.asarray(out_clean["x"]).tobytes()
    assert out2["toured"] == 1
    assert list(nbs.hop_root.iterdir()) == []


# ---------------------------------------------------------------------------
# supervisor escalation + lease stealing (spot-market semantics)
# ---------------------------------------------------------------------------


def test_reclaim_escalates_sigterm_to_sigkill(fab, monkeypatch):
    """S2: a worker that ignores SIGTERM (hung handler) must still die —
    the notice is a deadline, and the supervisor SIGKILLs when it expires
    (exactly EC2's behavior at the end of the 2-minute grace)."""
    sup, js = fab
    monkeypatch.setenv("REPRO_CHAOS_IGNORE_SIGTERM", "1")
    sup.spawn("stubborn", serve_only=True)
    t0 = time.monotonic()
    rc = sup.reclaim("stubborn", notice=True, wait_s=1.5)
    waited = time.monotonic() - t0
    assert rc == -signal.SIGKILL  # escalation, not a clean exit
    assert 1.0 < waited < 30.0  # bounded by wait_s + the kill reap
    assert "stubborn" not in sup.workers


def test_shutdown_escalates_on_sigterm_ignorers(fab, monkeypatch):
    """S2: shutdown() SIGTERMs the fleet, waits a bounded window, then
    SIGKILLs stragglers — a hung worker cannot wedge teardown."""
    sup, js = fab
    sup.spawn("polite", serve_only=True)
    monkeypatch.setenv("REPRO_CHAOS_IGNORE_SIGTERM", "1")
    sup.spawn("hung", serve_only=True)
    procs = {n: h.proc for n, h in sup.workers.items()}
    t0 = time.monotonic()
    sup.shutdown(wait_s=1.5)
    assert time.monotonic() - t0 < 60.0
    assert sup.workers == {}
    for proc in procs.values():
        assert proc.poll() is not None  # everyone is dead and reaped
    assert procs["hung"].returncode == -signal.SIGKILL


def test_lease_expiry_steal_after_holder_sigkill(fab):
    """S3: the holder is SIGKILLed BETWEEN heartbeats; its lease must expire
    on its own and become claimable by a steal=False rival, which then
    drives the job to a bit-identical product."""
    from repro.chaos import faults

    sup, js = fab
    clean = _run_clean(sup, js)

    job = js.create_job(JOB_INPUT)
    lease_s = 3.0
    # die exactly between heartbeats: the first renew_lease SIGKILLs the
    # holder, so the on-disk lease still has most of its term to run
    with faults.arm({"point": "lease.before_renew", "action": "sigkill",
                     "role": "worker"}):
        h = sup.spawn("holder", job_id=job.job_id, steps=40, publish_every=5,
                      step_ms=100, lease_s=lease_s, wait=False)
    assert h.wait(timeout=60) == -signal.SIGKILL
    sup.workers.pop("holder", None)

    j = js.read_job(job.job_id)
    assert j.lease_owner == "holder" and j.leased()  # dead but still leased
    # a polite rival (steal=False) must NOT claim a live lease...
    assert js.svc_get_job(job.job_id, worker="rival", steal=False) is None
    # ...until it expires on its own (no release path ran: the holder is gone)
    deadline = time.monotonic() + lease_s + 10
    while js.read_job(job.job_id).leased():
        assert time.monotonic() < deadline, "lease never expired"
        time.sleep(0.1)
    stolen = js.svc_get_job(job.job_id, worker="rival", lease_s=60.0, steal=False)
    assert stolen is not None and stolen.lease_owner == "rival"
    js.release(job.job_id)  # hand it back so a real worker can claim it

    # wait=False: the rescue job is tiny and can finish before the ping lands
    sup.spawn("rescuer", job_id=job.job_id, steps=40, publish_every=5,
              step_ms=1, wait=False)
    assert sup.workers["rescuer"].wait(timeout=60) == EXIT_FINISHED
    assert _product_bytes(js, job.job_id) == clean


# ---------------------------------------------------------------------------
# multi-host fleet: registry + agent + re-resolution (the PR-8 headline)
# ---------------------------------------------------------------------------


def test_supervisor_adopts_agent_worker_and_reclaims_through_it(tmp_path):
    """adopt(): the supervisor manages a worker it never forked. Signals and
    exit codes travel over the agent's wire services, and the agent reports
    the exit to the registry (exit codes beat heartbeat-gap inference)."""
    from repro.fabric.agent import Agent, AgentClient
    from repro.fabric.registry import Registry, RegistryClient, RegistryServer

    registry = Registry(suspect_after_s=0.5, dead_after_s=1.5)
    server = RegistryServer(registry).start()
    agent = Agent(store_root=str(tmp_path / "s3"), registry_addr=server.address,
                  worker_heartbeat_s=0.15).start()
    sup = FabricSupervisor(str(tmp_path / "s3"), transport="tcp")
    try:
        reg = RegistryClient(server.address)
        ac = AgentClient(agent.address)
        ac.spawn("W", {"serve_only": True}, respawn=False)
        rec = reg.wait_state("W", "alive", timeout=60)

        handle = sup.adopt("W", ac, address=rec["address"], pid=rec["pid"])
        assert handle.alive() and handle.pid == rec["pid"]
        assert handle.pid != os.getpid()  # genuinely not ours

        # reclaim-with-notice rides the agent wire: SIGTERM by *name*, the
        # worker publishes its notice path and exits EXIT_PREEMPTED
        rc = sup.reclaim("W", notice=True)
        assert rc == EXIT_PREEMPTED
        assert "W" not in sup.workers
        # the agent watched the exit and told the registry before any gap
        dead = reg.wait_state("W", "dead", timeout=10)
        assert dead["exit_rc"] == EXIT_PREEMPTED
        reg.close()
        ac.close()
    finally:
        sup.shutdown()
        agent.stop()
        server.stop()


def test_tcp_fleet_suspect_dead_agent_respawn_tour_resume_bit_identical(tmp_path):
    """The ISSUE-8 headline: a 3-node Fig.-8 tour over TCP against workers an
    *agent subprocess* spawned (the harness never forked them and reaches
    them only through registry pid records).

    * SIGSTOP freezes C's heartbeats without killing it: the registry's gap
      monitor — not an exit report — drives ALIVE -> SUSPECT -> DEAD.
    * SIGKILL then makes it a corpse; the agent reaps it and records the
      exit code in the registry.
    * The interrupted tour fails at the B->C move and leaves stage "read"
      published; B keeps its resident copy.
    * The agent provisions the replacement at a NEW ephemeral port; the
      registry bumps the generation; the driver's proxies re-resolve through
      node_resolver with no manual re-wiring.
    * Itinerary.resume completes to a bit-identical product, the hop
      namespace is clean, and no lease is left stranded.
    """
    import subprocess
    import sys

    from repro.core.itinerary import Itinerary
    from repro.fabric.agent import AgentClient, _src_dir
    from repro.fabric.registry import (
        Registry,
        RegistryClient,
        RegistryServer,
        node_resolver,
    )

    events = []
    registry = Registry(
        suspect_after_s=0.5, dead_after_s=1.5,
        on_state_change=lambda name, old, new, rec: events.append((name, old, new)),
    )
    server = RegistryServer(registry).start()
    reg_spec = f"{server.address[1]}:{server.address[2]}"
    js = JobStore(tmp_path / "jobs")
    agent_proc = subprocess.Popen(
        [sys.executable, "-m", "repro.fabric.agent",
         "--registry", reg_spec, "--store", str(tmp_path / "s3"),
         "--jobstore", str(tmp_path / "jobs"),
         "--name", "agent0", "--worker-heartbeat-s", "0.15"],
        env={**os.environ, "PYTHONPATH": _src_dir(), "JAX_PLATFORMS": "cpu"},
    )
    reg = RegistryClient(server.address)
    try:
        agent_rec = reg.wait_state("agent0", "alive", timeout=60)
        agent = AgentClient(agent_rec["address"])
        names = ("B", "C", "D")
        for name in names:
            agent.spawn(name, {"serve_only": True}, respawn=False)
        recs = {n: reg.wait_state(n, "alive", timeout=120) for n in names}

        nbs = NBS(tmp_path / "s3")
        nbs.add_node("A", mesh=None)
        for name in names:
            # resolver: the proxy re-resolves by NAME through the registry
            nbs.add_remote_node(name, recs[name]["address"],
                                resolver=node_resolver(reg, name))
        stages = _tour_stages(publish=True)
        x = np.random.default_rng(41).standard_normal((256, 64))

        job_clean = js.create_job({})
        out_clean = Itinerary(DHP(nbs, "A", js, chunk_bytes=1 << 14),
                              job_clean.job_id).run({"x": x.copy()}, stages)

        # -- failure detection is the registry's, not the harness's --------
        # SIGSTOP: the process lives (the agent keeps seeing it "running",
        # so no exit report) but its heartbeats stop — only the gap monitor
        # can conclude anything, and it must walk SUSPECT before DEAD
        os.kill(recs["C"]["pid"], signal.SIGSTOP)
        reg.wait_state("C", "suspect", timeout=15)
        reg.wait_state("C", "dead", timeout=15)
        assert ("C", "alive", "suspect") in events
        assert ("C", "suspect", "dead") in events
        # now make it a corpse; the agent reaps the child and files the rc
        os.kill(recs["C"]["pid"], signal.SIGKILL)
        deadline = time.monotonic() + 30
        while reg.resolve("C").get("exit_rc") != -signal.SIGKILL:
            assert time.monotonic() < deadline, "agent never reported the exit"
            time.sleep(0.05)

        # -- the interrupted tour ------------------------------------------
        job = js.create_job({})
        nbs.node("C").client.reconnect_timeout_s = 2.0  # fail fast, not 10s
        with pytest.raises(OSError):
            Itinerary(DHP(nbs, "A", js, chunk_bytes=1 << 14),
                      job.job_id).run({"x": x.copy()}, stages)
        j = js.read_job(job.job_id)
        assert j.status == STATUS_CKPT  # stage "read" committed before the kill
        assert nbs.call("B", "svc/ping")["resident"] >= 1  # holder kept its copy

        # -- agent-provisioned replacement + registry re-resolution --------
        agent.spawn("C", {"serve_only": True}, respawn=False)
        rec2 = reg.wait_state("C", "alive", timeout=120)
        assert rec2["generation"] > recs["C"]["generation"]
        assert tuple(rec2["address"]) != tuple(recs["C"]["address"])  # new port
        assert rec2["pid"] != recs["C"]["pid"]
        # the driver's next call re-resolves transparently: same proxy, no
        # manual re-wiring, answered by the NEW incarnation
        assert nbs.call("C", "svc/ping")["pid"] == rec2["pid"]

        it2 = Itinerary(DHP(nbs, "A", js, chunk_bytes=1 << 14), job.job_id)
        out2 = it2.resume(stages)
        assert [n for n, _ in it2.trace] == ["compute", "write"]
        assert np.asarray(out2["x"]).tobytes() == np.asarray(out_clean["x"]).tobytes()
        assert out2["toured"] == 1
        assert list(nbs.hop_root.iterdir()) == []  # clean hop_root
        assert not js.read_job(job.job_id).leased()  # no stranded lease

        agent.shutdown()
        agent.close()
        agent_proc.wait(timeout=30)
    finally:
        if agent_proc.poll() is None:
            agent_proc.kill()
            try:
                agent_proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                pass
        reg.close()
        server.stop()
