"""Process fabric: real RPC, real signals, kill-tested preemption.

The headline test SIGKILLs a worker process mid-job; a replacement process
restores from the last *committed* published CMI and the final product is
bit-identical to an uninterrupted run. A SIGTERM variant exercises the
2-minute-notice path (publish, then exit EXIT_PREEMPTED).

Every test is wrapped in a SIGALRM guard (pytest-timeout is not in the
image) so a hung worker can never wedge the suite.
"""

import os
import signal
import time

import numpy as np
import pytest

from repro.core import NBS, DHP
from repro.core.cmi import restore_cmi
from repro.core.jobstore import JobStore, STATUS_CKPT, STATUS_FINISHED
from repro.core.preemption import SpotSchedule
from repro.fabric import wire
from repro.fabric.proxy import RemoteStateRef
from repro.fabric.supervisor import FabricSupervisor
from repro.fabric.worker import EXIT_FINISHED, EXIT_NO_JOB, EXIT_PREEMPTED

PER_TEST_TIMEOUT_S = int(os.environ.get("NAVP_TEST_TIMEOUT", "180"))


@pytest.fixture(autouse=True)
def _alarm_guard():
    """Per-test wall-clock guard: process-spawning tests must never hang."""

    def on_alarm(signum, frame):
        raise TimeoutError(f"fabric test exceeded {PER_TEST_TIMEOUT_S}s")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(PER_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture
def fab(tmp_path):
    """(supervisor, jobstore, store_root) with guaranteed worker cleanup."""
    jroot = tmp_path / "jobs"
    sup = FabricSupervisor(str(tmp_path / "s3"), str(jroot))
    try:
        yield sup, JobStore(jroot)
    finally:
        sup.shutdown()


def _product_bytes(js: JobStore, job_id: str) -> bytes:
    job = js.read_job(job_id)
    assert job.status == STATUS_FINISHED and job.product
    state, _ = restore_cmi(js.cmi_root(job_id), job.product)
    return state["w"].tobytes() + str(state["t"]).encode()


# ---------------------------------------------------------------------------
# wire
# ---------------------------------------------------------------------------


def test_wire_roundtrip_both_codecs():
    msgs = [
        {"svc": "svc/hop", "kwargs": {"cmi": "hop-abc", "io_threads": 4}},
        {"blob": b"\x00\xffbytes", "nested": [1, 2.5, None, "x"]},
    ]
    for prefer in (True, False):
        for msg in msgs:
            framed = wire.encode(msg, prefer_msgpack=prefer)
            body = framed[4:]
            assert wire.decode_body(body[:1], body[1:]) == msg


def test_wire_rejects_bad_frames():
    with pytest.raises(wire.WireError):
        wire.decode_body(b"Z", b"{}")


# ---------------------------------------------------------------------------
# RPC: RemoteNode proxy over a live worker process
# ---------------------------------------------------------------------------


def test_remote_node_rpc_ping_hop_fetch(fab, tmp_path):
    sup, _ = fab
    handle = sup.spawn("B", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("B", handle.address)

    info = nbs.call("B", "svc/ping")
    assert info["node"] == "B" and info["pid"] == handle.pid
    assert info["pid"] != os.getpid()  # genuinely another process

    # unknown service surfaces as RemoteError with the remote traceback
    with pytest.raises(wire.RemoteError, match="no service"):
        nbs.call("B", "svc/nope")

    # store-mediated hop: state lands in the worker; receipt comes back
    dhp = DHP(nbs, "A")
    src = {"x": np.arange(64, dtype=np.float64), "step": 7}
    ref = dhp.hop(dict(src), "B", via="store")
    assert isinstance(ref, RemoteStateRef) and ref.leaves == 2 and ref.step == 7
    assert dhp.node == "B"

    # the transit hop-CMI was GC'd inside the worker after restore
    fetched = nbs.call("B", "svc/fetch", token=ref.token)
    names = {p.name for p in nbs.hop_root.iterdir()}
    assert fetched["cmi"] in names and len(names) == 1

    back, _ = restore_cmi(nbs.hop_root, fetched["cmi"])
    assert back["x"].tobytes() == src["x"].tobytes()
    assert int(back["step"]) == 7

    nbs.remove_node("B")  # closes the client socket
    # serve-only workers must still honor the SIGTERM notice path
    assert sup.reclaim("B", notice=True) == EXIT_PREEMPTED


def test_itinerary_rejects_remote_stage(fab, tmp_path):
    """Itineraries run stage fns on local state; a stage landing on a
    process-backed node must fail loudly, not feed the receipt to fn."""
    from repro.core.itinerary import Itinerary, Stage

    sup, _ = fab
    handle = sup.spawn("B", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("B", handle.address)
    it = Itinerary(DHP(nbs, "A"))
    with pytest.raises(NotImplementedError, match="process-backed"):
        it.run({"x": np.ones(4)}, [Stage("B", lambda s: s, "read")])


def test_remote_jobstore_services(fab):
    sup, js = fab
    job = js.create_job({"seed": 1})
    handle = sup.spawn("B", serve_only=True)
    nbs = NBS(sup.store_root)
    nbs.add_remote_node("B", handle.address)
    assert nbs.call("B", "svc/list_jobs") == [[job.job_id, "new"]]
    got = nbs.call("B", "svc/get_job", job_id=job.job_id, worker="tester")
    assert got["job_id"] == job.job_id and got["lease_owner"] == "tester"
    # leased now -> a claim-next from another caller finds nothing
    assert nbs.call("B", "svc/get_job", worker="rival") is None


# ---------------------------------------------------------------------------
# kill-tested preemption (the acceptance test)
# ---------------------------------------------------------------------------

JOB_INPUT = {"seed": 3, "n": 1024, "steps": 40, "publish_every": 5}


def _run_clean(sup: FabricSupervisor, js: JobStore) -> bytes:
    job = js.create_job(JOB_INPUT)
    out = sup.run_job(job.job_id, steps=40, publish_every=5, step_ms=1, timeout_s=120)
    assert out["incarnations"] == 1 and out["reclaims"] == 0
    return _product_bytes(js, job.job_id)


def test_sigkill_mid_job_resumes_bit_identical(fab, tmp_path):
    """SIGKILL (no notice) mid-job; a fresh process resumes from the last
    published CMI; the product is bit-identical to an uninterrupted run."""
    sup, js = fab
    clean = _run_clean(sup, js)

    job = js.create_job(JOB_INPUT)
    sched = SpotSchedule(preempt_steps=(10,), max_preemptions=1)
    out = sup.run_job(
        job.job_id, schedule=sched, notice=False,
        steps=40, publish_every=5, step_ms=20, timeout_s=300,
    )
    assert out["reclaims"] == 1 and out["incarnations"] == 2
    assert _product_bytes(js, job.job_id) == clean


def test_sigterm_notice_publishes_then_resumes_bit_identical(fab):
    """The 2-minute-notice path: SIGTERM -> worker publishes a CMI, exits
    EXIT_PREEMPTED; replacement resumes to a bit-identical product."""
    sup, js = fab
    clean = _run_clean(sup, js)

    job = js.create_job(JOB_INPUT)
    name = "victim-0"
    sup.spawn(name, job_id=job.job_id, steps=40, publish_every=5,
              step_ms=25, grace_s=30)
    # wait for the worker to get past its first published checkpoint
    # (svc_publish_job sets status and cmi atomically under the job lock)
    j = js.wait_for_status(job.job_id, STATUS_CKPT, timeout_s=60)
    assert j.cmi is not None

    rc = sup.reclaim(name, notice=True)
    assert rc == EXIT_PREEMPTED
    j = js.read_job(job.job_id)
    assert j.status == STATUS_CKPT and j.cmi is not None

    sup.spawn("victim-1", job_id=job.job_id, steps=40, publish_every=5, step_ms=1)
    assert sup.workers["victim-1"].wait(timeout=60) == EXIT_FINISHED
    assert _product_bytes(js, job.job_id) == clean


def test_concurrent_claimants_one_winner(fab):
    """The jobstore's fcntl leases under genuinely concurrent processes:
    exactly one claimant wins the job; the others exit EXIT_NO_JOB."""
    sup, js = fab
    job = js.create_job({"seed": 5, "n": 256, "steps": 150, "publish_every": 25})
    # wait=False: the claimants race for the lease from the moment they
    # start, and a loser may exit before it can ever be pinged
    handles = [
        sup.spawn(f"claimant-{i}", claim=True, steps=150, publish_every=25,
                  step_ms=30, lease_s=300, wait=False)
        for i in range(3)
    ]
    rcs = sorted(h.wait(timeout=120) for h in handles)
    assert rcs == [EXIT_FINISHED, EXIT_NO_JOB, EXIT_NO_JOB]
    assert js.read_job(job.job_id).status == STATUS_FINISHED


def test_supervisor_respawns_on_crash(fab):
    """A worker that dies without any schedule (rogue kill -9 from outside
    the supervisor's reclaim path) is detected and replaced."""
    sup, js = fab
    job = js.create_job(JOB_INPUT)
    import threading

    def assassin():
        # wait for the first checkpoint, then murder whatever worker exists
        js.wait_for_status(job.job_id, STATUS_CKPT, timeout_s=60)
        if sup.workers:
            h = next(iter(sup.workers.values()))
            try:
                os.kill(h.pid, signal.SIGKILL)
            except ProcessLookupError:
                pass

    t = threading.Thread(target=assassin, daemon=True)
    t.start()
    out = sup.run_job(job.job_id, steps=40, publish_every=5, step_ms=20, timeout_s=300)
    t.join(timeout=10)
    assert out["incarnations"] >= 2
    assert js.read_job(job.job_id).status == STATUS_FINISHED


# ---------------------------------------------------------------------------
# streaming hops (svc/hop_stream): disk-bypassing transport + fallback
# ---------------------------------------------------------------------------


def _fetch_state(nbs, token):
    fetched = nbs.call("W", "svc/fetch", token=token, drop=False)
    state, _ = restore_cmi(nbs.hop_root, fetched["cmi"])
    return state


def test_stream_hop_bypasses_disk_bit_identical(fab, tmp_path):
    """via="auto" against a process-backed node streams: no hop-CMI ever
    touches the store, and the fetched state is bit-identical."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(1).standard_normal((500, 64)), "step": 9}
    ref = dhp.hop(dict(src), "W")
    assert isinstance(ref, RemoteStateRef) and ref.via == "stream"
    assert ref.step == 9 and dhp.node == "W"
    # the whole point: nothing transited the shared store
    assert list(nbs.hop_root.iterdir()) == []

    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes() and back["step"] == 9


def test_stream_delta_second_hop_sends_only_changed_chunks(fab, tmp_path):
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)  # 16 KiB chunks

    src = {"x": np.random.default_rng(2).standard_normal((1000, 64))}
    dhp.hop(dict(src), "W")
    full = dict(wnode.last_stream_receipt)
    assert full["ref_chunks"] == 0

    # mutate ~10% of the rows; the repeat hop deltas against the resident
    src2 = {"x": src["x"].copy()}
    src2["x"][:100] += 1.0
    ref2 = dhp.hop(dict(src2), "W")
    delta = dict(wnode.last_stream_receipt)
    assert ref2.via == "stream"
    assert delta["ref_chunks"] > 0 and delta["data_chunks"] < full["data_chunks"] / 2
    assert delta["sent_bytes"] < full["sent_bytes"] / 2

    back = _fetch_state(nbs, ref2.token)
    assert back["x"].tobytes() == src2["x"].tobytes()


def test_stream_failure_falls_back_to_store_transparently(fab, tmp_path):
    """Receiver aborts mid-stream (fault injection, as a dying receiver
    would): dhp.hop transparently retries via the store-mediated path and
    the state still lands bit-identical."""
    sup, _ = fab
    handle = sup.spawn("W", serve_only=True)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    wnode = nbs.add_remote_node("W", handle.address)
    wnode._stream_fail_after = 2  # receiver dies after 2 chunks, every time
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)

    src = {"x": np.random.default_rng(3).standard_normal((500, 64)), "step": 4}
    ref = dhp.hop(dict(src), "W")  # via=auto -> stream -> fallback
    assert isinstance(ref, RemoteStateRef) and ref.via == "store"
    assert ref.step == 4
    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes()
    # nothing half-streamed became resident: only the store-hop state lives
    assert nbs.call("W", "svc/ping")["resident"] == 1


def test_stream_midkill_falls_back_to_respawned_worker(fab, tmp_path):
    """SIGKILL the destination worker mid-stream. The sender's stream fails;
    a replacement worker comes up at the SAME socket path (respawn-in-place);
    the transparent store-mediated fallback reconnects and completes, and
    the state is bit-identical."""
    import threading

    sup, _ = fab
    sock_path = os.path.join(sup.socket_dir, "W-fixed.sock")
    handle = sup.spawn("W", serve_only=True, socket_path=sock_path)
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("A", mesh=None)
    nbs.add_remote_node("W", handle.address)
    # ~256 chunks of 16 KiB with a 20 ms pause between sends: a multi-second
    # kill window no scheduler hiccup can miss
    dhp = DHP(nbs, "A", chunk_bytes=1 << 14)
    src = {"x": np.random.default_rng(4).standard_normal((4096, 64)), "step": 8}

    killed = threading.Event()

    def assassin():
        time.sleep(0.5)  # stream setup + first chunks are long gone by now
        sup.reclaim("W", notice=False)  # SIGKILL, no notice
        sup.spawn("W", serve_only=True, socket_path=sock_path)
        killed.set()

    t = threading.Thread(target=assassin, daemon=True)
    t.start()
    os.environ["REPRO_STREAM_CHUNK_PAUSE_S"] = "0.02"
    try:
        ref = dhp.hop(dict(src), "W")
    finally:
        os.environ.pop("REPRO_STREAM_CHUNK_PAUSE_S", None)
        t.join(timeout=30)
    assert killed.is_set(), "worker was never killed mid-stream"
    assert isinstance(ref, RemoteStateRef) and ref.via == "store"
    back = _fetch_state(nbs, ref.token)
    assert back["x"].tobytes() == src["x"].tobytes() and back["step"] == 8
