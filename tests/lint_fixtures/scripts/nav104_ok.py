"""Near-miss for NAV104, same script directory: an explicit fn_ref names a
register_stage'd stage, so the worker resolves it without importing this
file — lints clean."""

from repro.core.itinerary import Stage


def read_granules(s):
    return {**s, "granules": 6}


stages = [
    Stage("data-host", read_granules, "read", fn_ref="app:read_granules"),
]
