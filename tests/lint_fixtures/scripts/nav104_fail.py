"""Stage.fn defined in a script (this directory has no __init__.py): the
function imports as __main__, which no worker process can resolve."""

from repro.core.itinerary import Stage


def read_granules(s):
    return {**s, "granules": 6}


stages = [
    Stage("data-host", read_granules, "read"),  # EXPECT: NAV104
]
