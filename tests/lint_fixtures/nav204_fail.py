"""Running worker thread across a hop: the thread exists only in the
source process; the join after the boundary waits on a thread the resumed
process never started."""

import threading


def prefetch(s):
    s["ready"] = True


def tour(dhp, state):
    loader = threading.Thread(target=prefetch, args=(state,))
    loader.start()
    state = dhp.hop(state, "compute-host")  # EXPECT: NAV204
    loader.join()
    return state
