"""In-place mutation of published state: the checkpoint's token describes
the object as it was at publish time; mutating the same object afterwards
silently diverges from what a replay would restore."""


def checkpoint(dhp, job_id, state):
    dhp.publish(job_id, "ckpt", state, step=3)
    state["weights"] = state["weights"] * 0.5  # EXPECT: NAV402
    state = dhp.hop(state, "write-host")
    return state
