# This __init__.py makes the fixtures in this directory "package modules"
# in navlint's eyes (importable by a worker), so NAV104 stays quiet and
# each fixture isolates exactly the rule named in its filename. The
# fixtures under scripts/ deliberately have NO __init__.py — that is the
# NAV104 surface. Fixtures are linted, never imported or executed.
#
# Golden contract: every `# EXPECT: NAVxxx` comment marks the exact line
# navlint must report that code at; a fixture without EXPECT comments must
# lint clean (the near-miss half of each rule's pair).
