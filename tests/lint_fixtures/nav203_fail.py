"""Held lock across a publish: the checkpoint freezes a world in which the
lock is taken, but the resumed process has a fresh, unlocked lock — the
release after the boundary guards nothing."""

import threading


def checkpoint(dhp, job_id, state):
    guard = threading.Lock()
    guard.acquire()
    dhp.publish(job_id, "ckpt", state, step=2)  # EXPECT: NAV203
    guard.release()
    return state
