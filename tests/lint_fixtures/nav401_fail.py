"""Hop to a destination the NBS never declared: the tour dies at runtime
with an unknown-node error, after work has already been done."""

from repro.core.itinerary import Stage
from repro.core.nbs import NBS
from repro.fabric.worker import tour_read, tour_write


def build(dhp, state):
    nbs = NBS("/tmp/navp-fixture")
    nbs.add_node("data-host")
    nbs.add_node("compute-host")

    stages = [
        Stage("data-host", tour_read, "read"),
        Stage("archive-host", tour_write, "write"),  # EXPECT: NAV401
    ]

    state = dhp.hop(state, "gpu-host")  # EXPECT: NAV401
    return nbs, stages, state
