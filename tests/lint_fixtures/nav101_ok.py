"""Near-miss for NAV101: a function imported from a package module is
worker-addressable — same tour shape, no lambda, lints clean."""

from repro.core.itinerary import Itinerary, Stage
from repro.fabric.worker import tour_read


def build_tour(dhp, job_id):
    itinerary = Itinerary(dhp, job_id)
    stages = [
        Stage("data-host", tour_read, "read"),
    ]
    return itinerary, stages
