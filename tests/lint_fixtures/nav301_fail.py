"""Nondeterminism inside a stage between publish points: a replayed stage
must recompute bit-identical state, but wall-clock time and unseeded RNG
draws differ on every run."""

import random
import time

import numpy as np

from repro.core.itinerary import Stage


def compute(s):
    s = dict(s)
    s["stamp"] = time.time()  # EXPECT: NAV301
    s["jitter"] = random.random()  # EXPECT: NAV301
    rng = np.random.default_rng()  # EXPECT: NAV301
    s["noise"] = float(rng.normal())
    return s


stages = [
    Stage("compute-host", compute, "compute"),
]
