"""Near-miss for NAV401: every stage destination and hop target appears in
the module's add_node declarations."""

from repro.core.itinerary import Stage
from repro.core.nbs import NBS
from repro.fabric.worker import tour_read, tour_write


def build(dhp, state):
    nbs = NBS("/tmp/navp-fixture")
    nbs.add_node("data-host")
    nbs.add_node("compute-host")
    nbs.add_node("archive-host")

    stages = [
        Stage("data-host", tour_read, "read"),
        Stage("archive-host", tour_write, "write"),
    ]

    state = dhp.hop(state, "compute-host")
    return nbs, stages, state
