"""Live generator consumed after a hop: generator frames do not pickle, so
the iterator's position is lost at the boundary."""


def granule_batches(xs):
    for x in xs:
        yield x


def tour(dhp, state):
    batches = granule_batches(state["granules"])
    state = dhp.hop(state, "compute-host")  # EXPECT: NAV205
    state["first"] = next(batches)
    return state
