"""Near-miss for NAV205: the generator is materialized into a list before
the hop; plain data crosses the boundary."""


def granule_batches(xs):
    for x in xs:
        yield x


def tour(dhp, state):
    batches = list(granule_batches(state["granules"]))
    state = dhp.hop(state, "compute-host")
    state["first"] = batches[0]
    return state
