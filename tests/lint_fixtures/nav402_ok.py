"""Near-miss for NAV402: the post-publish update rebuilds the state into a
fresh binding first, so the published object is never mutated."""


def checkpoint(dhp, job_id, state):
    dhp.publish(job_id, "ckpt", state, step=3)
    state = {**state, "weights": state["weights"] * 0.5}
    state = dhp.hop(state, "write-host")
    return state
