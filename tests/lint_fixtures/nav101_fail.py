"""Lambda as Stage.fn: no importable name, silently localizes remotely."""

from repro.core.itinerary import Itinerary, Stage


def build_tour(dhp, job_id):
    itinerary = Itinerary(dhp, job_id)
    stages = [
        Stage("data-host", lambda s: {**s, "read": True}, "read"),  # EXPECT: NAV101
    ]
    return itinerary, stages
