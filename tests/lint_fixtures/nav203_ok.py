"""Near-miss for NAV203: the critical section closes before the publish,
so no lock state is live at the boundary."""

import threading


def checkpoint(dhp, job_id, state):
    guard = threading.Lock()
    guard.acquire()
    state = dict(state)
    guard.release()
    dhp.publish(job_id, "ckpt", state, step=2)
    return state
