"""Near-miss for NAV102: the stage fn is module-level (the scale rides in
the state instead of a closure cell) — importable, lints clean."""

from repro.core.itinerary import Stage


def scaled(s):
    return {**s, "x": s["x"] * s["scale"]}


def build_stages():
    return [
        Stage("compute-host", scaled, "scale"),
    ]
