"""Near-miss for NAV103: a module-qualified function attribute (imported
module alias) is importable by the worker — lints clean."""

import repro.fabric.worker as fw
from repro.core.itinerary import Stage


def build_stages():
    return [
        Stage("compute-host", fw.tour_compute, "compute"),
    ]
