"""Near-miss for NAV204: the thread is joined before the hop, so nothing
process-local is live at the boundary."""

import threading


def prefetch(s):
    s["ready"] = True


def tour(dhp, state):
    loader = threading.Thread(target=prefetch, args=(state,))
    loader.start()
    loader.join()
    state = dhp.hop(state, "compute-host")
    return state
