"""Suppression fixture: the violations here are real, but each carries a
navlint disable comment — the file must lint clean with suppressions
counted, demonstrating both line and file-scoped grammar."""
# navlint: disable-file=NAV301

import time

from repro.core.itinerary import Stage


def compute(s):
    s = dict(s)
    s["stamp"] = time.time()
    return s


stages = [
    Stage("compute-host", compute, "compute"),
    Stage("compute-host", lambda s: s, "id"),  # navlint: disable=NAV101
]
