"""Live socket passed into a publish payload: sockets do not pickle, and
even a reference held across the boundary points at a dead fd on resume."""

import socket


def checkpoint(dhp, job_id, state):
    feed = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    feed.connect(("127.0.0.1", 9470))
    dhp.publish(job_id, "ckpt", {"state": state, "feed": feed}, step=1)  # EXPECT: NAV202
    return feed.recv(1024)
