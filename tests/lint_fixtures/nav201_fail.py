"""Open file handle carried across a hop: the descriptor is process-local
state that cannot ride in the CMI — it is dead on the destination node."""


def tour(dhp, state):
    log = open("/tmp/tour.log", "a")
    log.write("leaving\n")
    state = dhp.hop(state, "compute-host")  # EXPECT: NAV201
    log.write("arrived\n")
    log.close()
    return state
