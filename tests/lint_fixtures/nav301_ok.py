"""Near-miss for NAV301: the RNG is seeded from state, and the clock used
is time.monotonic for cost measurement only (explicitly allowed) — every
replay draws the same stream."""

import time

import numpy as np

from repro.core.itinerary import Stage


def compute(s):
    s = dict(s)
    t0 = time.monotonic()
    rng = np.random.default_rng(s["seed"])
    s["noise"] = float(rng.normal())
    s["compute_cost_s"] = time.monotonic() - t0
    return s


stages = [
    Stage("compute-host", compute, "compute"),
]
