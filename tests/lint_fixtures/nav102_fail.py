"""Closure as Stage.fn: qualname contains <locals>, not importable."""

from repro.core.itinerary import Stage


def build_stages(scale):
    def scaled(s):
        return {**s, "x": s["x"] * scale}

    return [
        Stage("compute-host", scaled, "scale"),  # EXPECT: NAV102
    ]
