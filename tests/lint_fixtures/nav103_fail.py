"""Bound method / functools.partial as Stage.fn: the worker would misbind
`self`, and a partial has no importable name."""

from functools import partial

from repro.core.itinerary import Stage


def scale(s, k):
    return {**s, "x": s["x"] * k}


class Tour:
    def step(self, s):
        return s

    def stages(self):
        return [
            Stage("compute-host", self.step, "step"),  # EXPECT: NAV103
            Stage("compute-host", partial(scale, k=2.0), "scale"),  # EXPECT: NAV103
        ]
