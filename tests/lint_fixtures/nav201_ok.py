"""Near-miss for NAV201: both handles are finished before the hop — one
closed explicitly, one scoped by a with-block that ends first."""


def tour(dhp, state):
    log = open("/tmp/tour.log", "a")
    log.write("leaving\n")
    log.close()
    with open("/tmp/tour.meta", "w") as meta:
        meta.write("granules=6\n")
    state = dhp.hop(state, "compute-host")
    return state
