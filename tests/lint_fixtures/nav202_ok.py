"""Near-miss for NAV202: the socket is drained and closed before the
publish, and only plain data enters the payload."""

import socket


def checkpoint(dhp, job_id, state):
    feed = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    feed.connect(("127.0.0.1", 9470))
    header = feed.recv(1024)
    feed.close()
    dhp.publish(job_id, "ckpt", {"state": state, "header": header}, step=1)
    return header
