"""Registry unit tests: the heartbeat-gap state machine, generations, and
name re-resolution — all in-process (no worker subprocesses), so they run
at tier-1 speed. The process-level fleet story (agent subprocess, SIGKILL,
respawn) lives in tests/test_fabric.py and the chaos matrix's fleet cells.
"""

import os
import signal
import time

import pytest

from repro.fabric import wire
from repro.fabric.registry import (
    ALIVE,
    DEAD,
    SUSPECT,
    Registry,
    RegistryClient,
    RegistryServer,
    node_resolver,
    tcp_address,
)

PER_TEST_TIMEOUT_S = int(os.environ.get("NAVP_TEST_TIMEOUT", "180"))


@pytest.fixture(autouse=True)
def _alarm_guard():
    def on_alarm(signum, frame):
        raise TimeoutError(f"registry test exceeded {PER_TEST_TIMEOUT_S}s")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.alarm(PER_TEST_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)


# ---------------------------------------------------------------------------
# the state machine (no transport, manual sweeps with injected clocks)
# ---------------------------------------------------------------------------


def test_tcp_address_parses_specs():
    assert tcp_address("127.0.0.1:7000") == ("tcp", "127.0.0.1", 7000)
    assert tcp_address(":7000") == ("tcp", "127.0.0.1", 7000)
    assert tcp_address("host.example:0") == ("tcp", "host.example", 0)


def test_gap_drives_alive_suspect_dead_with_callbacks():
    events = []
    reg = Registry(suspect_after_s=1.0, dead_after_s=3.0,
                   on_state_change=lambda n, o, s, r: events.append((n, o, s)))
    reg.register("W", ("tcp", "127.0.0.1", 7001), pid=123)
    t0 = reg.resolve("W").last_heartbeat

    reg.sweep(now=t0 + 0.5)
    assert reg.resolve("W").state == ALIVE
    reg.sweep(now=t0 + 1.5)
    assert reg.resolve("W").state == SUSPECT
    reg.sweep(now=t0 + 2.5)  # suspect is not dead yet
    assert reg.resolve("W").state == SUSPECT
    reg.sweep(now=t0 + 3.5)
    assert reg.resolve("W").state == DEAD
    assert events == [("W", ALIVE, SUSPECT), ("W", SUSPECT, DEAD)]

    # a sign of life resurrects the record (slow != gone)
    assert reg.heartbeat("W") == ALIVE
    assert events[-1] == ("W", DEAD, ALIVE)


def test_one_sweep_walks_straight_to_dead_after_a_long_gap():
    """A monitor that was itself stalled (driver paused, clock jump) must
    not leave a long-gapped node parked in SUSPECT."""
    reg = Registry(suspect_after_s=1.0, dead_after_s=3.0)
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    t0 = reg.resolve("W").last_heartbeat
    reg.sweep(now=t0 + 10.0)
    assert reg.resolve("W").state == DEAD


def test_reregistration_bumps_generation_and_replaces_address():
    events = []
    reg = Registry(on_state_change=lambda n, o, s, r: events.append((n, o, s)))
    g1 = reg.register("W", ("tcp", "127.0.0.1", 7001), pid=1)
    reg.report_exit("W", rc=-9)
    assert reg.resolve("W").state == DEAD
    assert reg.resolve("W").exit_rc == -9

    g2 = reg.register("W", ("tcp", "127.0.0.1", 7002), pid=2)
    rec = reg.resolve("W")
    assert g2 == g1 + 1 == rec.generation
    assert rec.address == ("tcp", "127.0.0.1", 7002) and rec.pid == 2
    assert rec.state == ALIVE and rec.exit_rc is None
    assert ("W", DEAD, ALIVE) in events  # respawn announced itself


def test_stale_generation_heartbeat_cannot_keep_the_record_alive():
    """A zombie predecessor outliving its replacement must not mask the new
    incarnation's death: its beats are answered "stale" and ignored."""
    reg = Registry(suspect_after_s=1.0, dead_after_s=3.0)
    g1 = reg.register("W", ("tcp", "127.0.0.1", 7001))
    g2 = reg.register("W", ("tcp", "127.0.0.1", 7002))
    t0 = reg.resolve("W").last_heartbeat

    assert reg.heartbeat("W", generation=g1) == "stale"
    reg.sweep(now=t0 + 1.5)
    assert reg.resolve("W").state == SUSPECT  # the zombie beat didn't refresh
    assert reg.heartbeat("W", generation=g2) == ALIVE
    assert reg.heartbeat("ghost") == "unknown"


def test_report_exit_beats_gap_inference():
    """An agent-observed exit marks DEAD immediately — no SUSPECT detour,
    no waiting out the heartbeat timeout."""
    events = []
    reg = Registry(on_state_change=lambda n, o, s, r: events.append((n, o, s)))
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    reg.report_exit("W", rc=-signal.SIGKILL)
    rec = reg.resolve("W")
    assert rec.state == DEAD and rec.exit_rc == -signal.SIGKILL
    assert events == [("W", ALIVE, DEAD)]
    reg.report_exit("ghost", rc=1)  # unknown names are a no-op, not a crash


# ---------------------------------------------------------------------------
# the wire service + re-resolution
# ---------------------------------------------------------------------------


@pytest.fixture
def served():
    registry = Registry(suspect_after_s=0.5, dead_after_s=1.5)
    server = RegistryServer(registry).start()
    client = RegistryClient(server.address)
    try:
        yield registry, server, client
    finally:
        client.close()
        server.stop()


def test_registry_server_round_trip(served):
    registry, server, reg = served
    g = reg.register("W", ("tcp", "127.0.0.1", 7001), pid=42, kind="worker",
                     meta={"host": "h1"})
    assert g == 1
    rec = reg.resolve("W")
    assert rec["address"] == ("tcp", "127.0.0.1", 7001)  # tuple-normalized
    assert rec["pid"] == 42 and rec["meta"] == {"host": "h1"}
    assert reg.heartbeat("W", generation=g) == ALIVE
    assert [r["name"] for r in reg.list_nodes()] == ["W"]
    reg.report_exit("W", rc=-9)
    assert reg.resolve("W")["state"] == DEAD
    reg.deregister("W")
    with pytest.raises(wire.RemoteError, match="unknown node"):
        reg.resolve("W")


def test_wait_state_times_out_with_last_seen_state(served):
    _, _, reg = served
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    with pytest.raises(TimeoutError, match="alive"):
        reg.wait_state("W", "dead", timeout=0.3)


def test_monitor_thread_suspects_then_revives_on_heartbeat(served):
    """The RegistryServer's own monitor (not a manual sweep) drives the
    transitions off the wall clock; a late heartbeat revives the record."""
    _, _, reg = served
    g = reg.register("W", ("tcp", "127.0.0.1", 7001))
    reg.wait_state("W", SUSPECT, timeout=10)
    assert reg.heartbeat("W", generation=g) == ALIVE
    assert reg.resolve("W")["state"] == ALIVE
    reg.wait_state("W", DEAD, timeout=10)  # and with no more beats: dead


def test_node_resolver_tracks_reregistration_and_degrades_to_none(served):
    _, _, reg = served
    resolve = node_resolver(reg, "W")
    assert resolve() is None  # unknown name: caller keeps its cached address
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    assert resolve() == ("tcp", "127.0.0.1", 7001)
    reg.register("W", ("tcp", "127.0.0.1", 7002))  # respawn moved it
    assert resolve() == ("tcp", "127.0.0.1", 7002)


def test_fabric_client_reresolves_respawned_server_through_registry(served, tmp_path):
    """The cache-invalidation story end to end, in-process: a FabricClient
    whose server died reconnects through node_resolver to the SAME name at a
    NEW port — the respawned incarnation answers, nobody retries the corpse."""
    from repro.core import NBS
    from repro.fabric.proxy import FabricClient
    from repro.fabric.server import NodeServer

    _, _, reg = served
    nbs = NBS(tmp_path / "s3")
    nbs.add_node("W", mesh=None)
    s1 = NodeServer(nbs, "W", ("tcp", "127.0.0.1", 0)).start()
    reg.register("W", s1.address, pid=os.getpid())
    client = FabricClient(s1.address, reconnect_timeout_s=10.0,
                          resolver=node_resolver(reg, "W"))
    try:
        assert client.request("svc/ping")["node"] == "W"
        s1.stop()  # no new connections to the old incarnation...
        client._sock.close()  # ...and the established one dies with it

        s2 = NodeServer(nbs, "W", ("tcp", "127.0.0.1", 0)).start()
        try:
            assert s2.address != s1.address  # genuinely a new port
            reg.register("W", s2.address, pid=os.getpid())
            # same proxy object: reconnect consults the resolver and lands
            # on the new address
            assert client.request("svc/ping")["node"] == "W"
            assert client.address == s2.address
        finally:
            s2.stop()
    finally:
        client.close()


def test_service_client_resends_after_connection_loss(served):
    """ServiceClient's blind reconnect-resend: a dropped connection between
    requests is invisible to the caller (every reg/* service is idempotent)."""
    _, _, reg = served
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    assert reg.resolve("W")["name"] == "W"
    reg._sock.close()  # sever the link behind the client's back
    assert reg.resolve("W")["name"] == "W"  # reconnect + resend, same answer


def test_dead_callback_releases_only_the_dead_workers_leases(tmp_path):
    """The DEAD transition is where supervisors hang lease policy: wired to
    JobStore.release_worker_leases, a confirmed-dead node's jobs become
    claimable immediately — no waiting out the remaining lease window — and
    other workers' live leases are untouched."""
    from repro.core.jobstore import JobStore

    js = JobStore(tmp_path / "jobs")
    j1 = js.create_job({"seed": 1})
    j2 = js.create_job({"seed": 2})
    assert js.svc_get_job(j1.job_id, worker="W", lease_s=3600).lease_owner == "W"
    assert js.svc_get_job(j2.job_id, worker="bystander", lease_s=3600) is not None

    released = []
    reg = Registry(
        suspect_after_s=0.5, dead_after_s=1.5,
        on_state_change=lambda name, old, new, rec: (
            released.extend(js.release_worker_leases(name)) if new == DEAD else None
        ),
    )
    reg.register("W", ("tcp", "127.0.0.1", 7001))
    t0 = reg.resolve("W").last_heartbeat
    reg.sweep(now=t0 + 10.0)  # long-gapped: straight to DEAD

    assert released == [j1.job_id]
    assert not js.read_job(j1.job_id).leased()
    assert js.read_job(j2.job_id).lease_owner == "bystander"  # untouched
    # a polite rival claims W's job NOW — the 3600s lease term is irrelevant
    stolen = js.svc_get_job(j1.job_id, worker="rival", steal=False)
    assert stolen is not None and stolen.lease_owner == "rival"


def test_heartbeat_loop_stops_when_superseded(served):
    """A start_heartbeat loop whose generation was superseded must stop
    beating (it is the zombie); the new generation's beats keep flowing."""
    _, _, reg = served
    g1 = reg.register("W", ("tcp", "127.0.0.1", 7001))
    stop = reg.start_heartbeat("W", g1, interval_s=0.05)
    try:
        g2 = reg.register("W", ("tcp", "127.0.0.1", 7002))  # supersede gen 1
        deadline = time.monotonic() + 5
        # with only the stale loop beating, the record must decay to SUSPECT:
        # proof the zombie's beats are being ignored AND its loop exits
        while reg.resolve("W")["state"] == ALIVE:
            assert time.monotonic() < deadline, "stale beats kept the record alive"
            time.sleep(0.05)
        assert reg.heartbeat("W", generation=g2) == ALIVE
    finally:
        stop.set()
