"""Delta CMIs: on-device change hints agree with host hashing; restores are
exact under arbitrary mutation patterns (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint import SaveOptions, load_checkpoint, save_checkpoint
from repro.checkpoint.serializer import load_manifest
from repro.core.delta import DeltaPolicy, DeltaTracker, device_changed_hints


def test_hints_match_serializer_grid():
    """Hint bitmap indices line up with the serializer's chunk grid: a save
    using the hints must produce exactly the same refs as hash-compare."""
    rng = np.random.default_rng(0)
    w = rng.standard_normal((40, 16)).astype(np.float32)
    t0 = {"w": jnp.asarray(w)}
    w2 = w.copy()
    w2[7] += 1.0
    w2[33] -= 1.0
    t1 = {"w": jnp.asarray(w2)}
    import tempfile

    root = tempfile.mkdtemp()
    cb = 16 * 16 * 4  # 16 rows/chunk
    save_checkpoint(root, "c0", t0, options=SaveOptions(chunk_bytes=cb))
    hints = device_changed_hints(t0, t1, chunk_bytes=cb)
    m_hint = save_checkpoint(
        root, "c1", t1, options=SaveOptions(chunk_bytes=cb, parent="c0", changed_hint=hints)
    )
    m_hash = save_checkpoint(root, "c2", t1, options=SaveOptions(chunk_bytes=cb, parent="c0"))
    assert m_hint.extra["stats"]["ref_chunks"] == m_hash.extra["stats"]["ref_chunks"]
    got, _ = load_checkpoint(root, "c1")
    np.testing.assert_array_equal(np.asarray(got["w"]), w2)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(4, 60),
    cols=st.integers(1, 12),
    muts=st.lists(st.tuples(st.integers(0, 59), st.integers(0, 11)), max_size=8),
    chunk_rows=st.integers(1, 16),
)
def test_delta_roundtrip_property(tmp_path_factory, rows, cols, muts, chunk_rows):
    root = tmp_path_factory.mktemp("delta")
    rng = np.random.default_rng(1)
    w = rng.standard_normal((rows, cols)).astype(np.float32)
    cb = chunk_rows * cols * 4
    save_checkpoint(root, "c0", {"w": w}, options=SaveOptions(chunk_bytes=cb))
    w2 = w.copy()
    for r, c in muts:
        if r < rows and c < cols:
            w2[r, c] += 1.0
    hints = device_changed_hints({"w": jnp.asarray(w)}, {"w": jnp.asarray(w2)}, chunk_bytes=cb)
    save_checkpoint(
        root, "c1", {"w": w2},
        options=SaveOptions(chunk_bytes=cb, parent="c0", changed_hint=hints),
    )
    got, _ = load_checkpoint(root, "c1")
    np.testing.assert_array_equal(np.asarray(got["w"]), w2)


def test_tracker_resets_chain():
    t = DeltaTracker(DeltaPolicy(full_every=3))

    class FakeStore:
        def cmi_root(self, _):
            return "/nonexistent"

    t.record_published("j", "a")
    t.record_published("j", "b")
    t.record_published("j", "c")
    # parent would be "c" but chain length forces a full CMI
    assert t.parent_for("j", FakeStore()) is None


def test_hints_skip_shape_mismatch():
    h = device_changed_hints({"w": jnp.zeros((4, 4))}, {"w": jnp.zeros((5, 4))})
    assert h == {}
