"""Pipeline parallelism: GPipe-over-ppermute == sequential stack."""

SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, stage_shardings

S, M, MB, D = 4, 6, 2, 16
mesh = jax.make_mesh((1, S), ("data", "model"))
rng = np.random.default_rng(0)
params = {
    "w": jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32),
    "b": jnp.asarray(rng.standard_normal((S, D)) * 0.1, jnp.float32),
}
x = jnp.asarray(rng.standard_normal((M, MB, D)), jnp.float32)

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

# sequential reference
ref = x
for s in range(S):
    pl = jax.tree_util.tree_map(lambda l: l[s], params)
    ref = jax.vmap(lambda mb: stage_fn(pl, mb))(ref)

params_sh = jax.tree_util.tree_map(jax.device_put, params, stage_shardings(params, mesh))
got = jax.jit(lambda p, xx: pipeline_forward(stage_fn, p, xx, mesh))(params_sh, x)
np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5, rtol=1e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential(subproc):
    out = subproc(SCRIPT, devices=4, timeout=420)
    assert "PIPELINE_OK" in out
