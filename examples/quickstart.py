"""Quickstart: train a model with application-initiated checkpointing.

The paper's Figure-7 flow in ~20 lines of user code: create a job, train,
publish CMIs at application-chosen points, kill it, resume, finish.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

import repro.launch.train as train

store = tempfile.mkdtemp(prefix="navp-quickstart-")

# Run 1: train to step 30, but a (simulated) spot reclaim lands at step 17.
# The worker publishes a CMI and exits; the supervisor provisions a fresh
# "instance" and resumes from the job store — same loss as an uninterrupted
# run (tested bitwise in tests/test_preemption.py).
loss = train.main([
    "--arch", "qwen3-1.7b", "--smoke",
    "--steps", "30", "--publish-every", "10",
    "--preempt-at", "17",
    "--store", store,
    "--seq-len", "64", "--batch", "8",
])
print(f"\nfinal loss: {loss:.4f}")
print(f"job store: {store}")

from repro.core.jobstore import JobStore  # noqa: E402

print("jobs:", JobStore(store).svc_list_jobs())  # [['1', 'finished']]
