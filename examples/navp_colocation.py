"""The paper's proof-of-concept, end to end: VIIRS→CrIS co-location as a
NavP itinerary (Figures 7 & 8).

Two nodes model the paper's second experiment: a *data host* (where granules
live) and a *compute host*. The program is written as a sequential itinerary
that hops to the data, hops back to compute, and hops again to publish — the
Lagrangian view — with `publish("ckpt")` after each stage so a reclaim
resumes mid-pipeline.

    PYTHONPATH=src python examples/navp_colocation.py

The same itinerary runs unchanged across *process-backed* nodes: register
them with ``nbs.add_remote_node(name, address)`` (see ``repro.fabric``) and
each stage executes inside the worker holding the state (`svc/run_stage`),
with node-to-node moves streamed worker-to-worker and the product streamed
back — no store on the happy path. The one requirement is that stage
functions live in an importable module (these ones are defined in a script's
``__main__``, so a remote runner would transparently fall back to fetching
the state and running them driver-side; move them into a package module to
ship the computation instead of the data).
"""

import sys
import tempfile

sys.path.insert(0, "src")

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import DHP, NBS, JobStore  # noqa: E402
from repro.core import colocation as co  # noqa: E402
from repro.core.itinerary import Itinerary, Stage  # noqa: E402
from repro.core.jobstore import STATUS_FINISHED  # noqa: E402

root = tempfile.mkdtemp(prefix="navp-coloc-")
nbs = NBS(root + "/s3")
nbs.add_node("data-host", mesh=None)     # granule storage server
nbs.add_node("compute-host", mesh=None)  # number-cruncher
store = JobStore(root + "/jobs")
job = store.create_job({"app": "viirs-cris-colocation"})
dhp = DHP(nbs, "compute-host", store)


# --- the science code, written as plain sequential stages ------------------
def read_granules(s):
    g = co.make_synthetic_granules(0, n_scans=6, viirs_pixels_per_scan=1600, viirs_lines_per_scan=8)
    print(f"  read {g['viirs_lat'].size} VIIRS pixels, {g['cris_lat'].size} CrIS FOVs")
    return {k: jnp.asarray(v) for k, v in g.items()}


def compute_vectors(s):
    los = co.cris_los_ecef(s["cris_lat"], s["cris_lon"], s["sat_pos"])   # Fig 7 line 10
    pos = co.viirs_pos_ecef(s["viirs_lat"], s["viirs_lon"])              # Fig 7 line 11
    return {**s, "los": los, "pos": pos}


def match(s):
    idx, cos, within = co.match_viirs_to_cris(s["pos"], s["los"], s["sat_pos"])  # line 13
    print(f"  matched {float(jnp.mean(within.astype(jnp.float32)))*100:.1f}% of pixels")
    return {**s, "idx": idx, "within": within}


def write_back(s):
    return s  # the publish after this stage is the "write" (Fig. 8)


# --- Figure 8: three hops between data and compute hosts -------------------
# NAV104 suppressed by intent: these stages live in a script, so remote
# runners localize the state and run them driver-side — exactly the
# degradation the module docstring documents. `python -m repro.analysis
# examples` keeps every OTHER hazard fatal.
itinerary = Itinerary(dhp, job.job_id)
stages = [
    Stage("data-host", read_granules, "read", publish=True),      # hop to the data  # navlint: disable=NAV104
    Stage("compute-host", compute_vectors, "geometry", publish=True),  # navlint: disable=NAV104
    Stage("compute-host", match, "match", publish=True),          # navlint: disable=NAV104
    Stage("data-host", write_back, "write"),                      # hop back to publish  # navlint: disable=NAV104
]
print("running itinerary:")
state = itinerary.run({}, stages)
print("  execution trace:", itinerary.trace)

prod = co.build_product(
    {"cris_lat": np.asarray(state["cris_lat"]), "viirs_rad": np.asarray(state["viirs_rad"])},
    state["idx"], state["within"],
)
dhp.publish(job.job_id, STATUS_FINISHED, product={
    "matched_frac": prod["matched_frac"],
    "cris_mean_rad": prod["cris_mean_rad"],
    "cris_match_count": prod["cris_match_count"],
})
print("job status:", store.svc_list_jobs())
print(f"product: matched_frac={prod['matched_frac']:.3f}, "
      f"mean matches/FOV={prod['cris_match_count'].mean():.1f}")
