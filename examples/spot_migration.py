"""Elastic spot migration: lose half the cluster mid-training, keep going.

Part 1 — in-process reclaim simulation: a training job starts on a 4×2
(data×model) mesh. At step 12 the spot market reclaims the instance; the
replacement is SMALLER — a 2×2 mesh. The CMI's sharding records remap by
axis name (divisibility-checked), so the same job resumes on the new
topology without any user code.

Part 2 — the process fabric makes the reclaim REAL: a worker runs in its own
OS process and the supervisor kills it with SIGKILL (a no-notice spot
reclaim) mid-job. A fresh process restores from the last published CMI and
finishes the job; the jobstore on the shared filesystem is the only medium
the two incarnations ever share.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spot_migration.py
"""

import os
import sys
import tempfile

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import repro.launch.train as train  # noqa: E402

store = tempfile.mkdtemp(prefix="navp-elastic-")
loss = train.main([
    "--arch", "granite-moe-1b-a400m", "--smoke",
    "--steps", "24", "--publish-every", "6",
    "--preempt-at", "12",
    "--remesh", "4x2,2x2",  # incarnation 0: 8 chips; incarnation 1: 4 chips
    "--store", store,
    "--seq-len", "64", "--batch", "8",
])
print(f"\nfinal loss after elastic 8→4 chip migration: {loss:.4f}")

# -- Part 2: process-per-node fabric, SIGKILL reclaim ------------------------
from repro.core.jobstore import STATUS_FINISHED, JobStore  # noqa: E402
from repro.core.preemption import SpotSchedule  # noqa: E402
from repro.fabric.supervisor import FabricSupervisor  # noqa: E402

fab_store = tempfile.mkdtemp(prefix="navp-fabric-")
job_root = tempfile.mkdtemp(prefix="navp-fabric-jobs-")
jobstore = JobStore(job_root)
job = jobstore.create_job({"seed": 11, "n": 4096, "steps": 40, "publish_every": 8})
with FabricSupervisor(fab_store, job_root) as sup:
    out = sup.run_job(
        job.job_id,
        schedule=SpotSchedule(preempt_steps=(16,), max_preemptions=1),
        notice=False,  # SIGKILL: no 2-minute warning, the process just dies
        steps=40, publish_every=8, step_ms=20, timeout_s=300,
    )
finished = jobstore.wait_for_status(job.job_id, STATUS_FINISHED, timeout_s=10)
print(
    f"fabric job {job.job_id}: {finished.status} at step {finished.step} "
    f"after {out['reclaims']} SIGKILL reclaim(s), "
    f"{out['incarnations']} worker process(es); product={finished.product}"
)
