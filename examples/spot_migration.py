"""Elastic spot migration: lose half the cluster mid-training, keep going.

A training job starts on a 4×2 (data×model) mesh. At step 12 the spot market
reclaims the instance; the replacement is SMALLER — a 2×2 mesh. The CMI's
sharding records remap by axis name (divisibility-checked), so the same job
resumes on the new topology without any user code.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/spot_migration.py
"""

import os
import sys
import tempfile

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

sys.path.insert(0, "src")

import repro.launch.train as train  # noqa: E402

store = tempfile.mkdtemp(prefix="navp-elastic-")
loss = train.main([
    "--arch", "granite-moe-1b-a400m", "--smoke",
    "--steps", "24", "--publish-every", "6",
    "--preempt-at", "12",
    "--remesh", "4x2,2x2",  # incarnation 0: 8 chips; incarnation 1: 4 chips
    "--store", store,
    "--seq-len", "64", "--batch", "8",
])
print(f"\nfinal loss after elastic 8→4 chip migration: {loss:.4f}")
