"""Serving as a preemptible job: the KV caches + position ARE the CMI.

An elastic fleet serves a batch of generation requests through the router
(``repro.serve``): requests join a rolling batch on whichever worker is
least loaded, one is live-migrated mid-generation over the streamed delta
hop, and then the spot market SIGKILLs a worker with no notice — its
in-flight requests resume on the survivor from their last published CMI,
*without re-prefilling* (with 32k contexts, prefill is exactly the "hours
of work" the paper refuses to throw away).

The reference transcripts come from an unperturbed single worker in the
same fleet environment, so the final assert is bit-for-bit.

    PYTHONPATH=src python examples/elastic_serve.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

from repro.core import JobStore  # noqa: E402
from repro.fabric.supervisor import FabricSupervisor  # noqa: E402
from repro.serve import ServeRouter  # noqa: E402
from repro.serve.scenarios import spawn_serve_worker, spot_reclaim  # noqa: E402

ENGINE = "model:qwen3-1.7b:smoke:seed=0"
REQUESTS = [
    {"id": f"r{i}", "prompt": [17 + 3 * i + j for j in range(12)], "max_new": 12}
    for i in range(4)
]

root = tempfile.mkdtemp(prefix="navp-serve-")
sup = FabricSupervisor(store_root=root + "/store", jobstore_root=root + "/jobs")
jobstore = JobStore(root + "/jobs")

try:
    # --- reference: one unperturbed worker defines the expected transcripts.
    # Same supervisor, same env — the fleet run below must reproduce these
    # byte for byte through every migration and kill.
    ref_handle = spawn_serve_worker(sup, "ref", engine_spec=ENGINE)
    ref_router = ServeRouter(jobstore=jobstore)
    ref_router.add_worker("ref", ref_handle.address)
    for req in REQUESTS:
        ref_router.admit(req["prompt"], req["max_new"], req_id=req["id"])
    ref_router.run_to_completion()
    reference = {req["id"]: ref_router.transcript(req["id"]) for req in REQUESTS}
    ref_router.close()
    sup.reclaim("ref", notice=True)
    print(f"reference worker done: {len(reference)} transcripts recorded")

    # --- the churn run: two workers, live migration, then a spot kill -------
    router = ServeRouter(jobstore=jobstore)
    for name in ("w0", "w1"):
        handle = spawn_serve_worker(sup, name, engine_spec=ENGINE,
                                    publish_every=3)
        router.add_worker(name, handle.address)
    for req in REQUESTS:
        router.admit(req["prompt"], req["max_new"], req_id=req["id"])
    for _ in range(3):
        router.step()

    victim = next(r for r in router.pending() if router.assignment[r] == "w0")
    event = router.migrate(victim, "w1")
    assert event["mode"] == "stream", event
    print(f"live-migrated {victim} w0 -> w1 mid-generation: "
          f"{event['chunks']} chunks ({event['data_chunks']} streamed, "
          f"{event['ref_chunks']} ref'd), zero re-prefill")
    for _ in range(2):
        router.step()

    # the spot market takes w0 with NO notice: SIGKILL, no flush. Its
    # requests resume on w1 from their last published CMI — re-generated
    # tokens overwrite transcript slots with identical values.
    out = spot_reclaim(sup, router, "w0", "w1", notice=False)
    print(f"w0 SIGKILLed (rc={out['rc']}); resumed on w1: {out['resumed']}")
    router.run_to_completion()

    for req in REQUESTS:
        got = router.transcript(req["id"])
        assert got == reference[req["id"]], f"{req['id']} diverged: {got}"
    print("all transcripts identical to the unperturbed run:")
    for req in REQUESTS:
        print(f"  {req['id']}: {reference[req['id']]}")
    router.close()
finally:
    sup.shutdown()
