"""Serving as a preemptible job: the KV caches + position ARE the CMI.

A batched generation job prefills once, decodes a few tokens, is reclaimed,
and a new instance resumes mid-generation from the published CMI — no
re-prefill. (With 32k contexts, prefill is exactly the "hours of work" the
paper refuses to throw away.)

    PYTHONPATH=src python examples/elastic_serve.py
"""

import sys
import tempfile

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core import DHP, NBS, JobStore  # noqa: E402
from repro.core.jobstore import STATUS_CKPT, STATUS_FINISHED  # noqa: E402
from repro.models import Model  # noqa: E402

cfg = get_smoke_config("qwen3-1.7b")
model = Model(cfg)
params, _ = model.init(jax.random.PRNGKey(0))

root = tempfile.mkdtemp(prefix="navp-serve-")
nbs = NBS(root + "/s3")
nbs.add_node("serve-0", mesh=None)
nbs.add_node("serve-1", mesh=None)
store = JobStore(root + "/jobs")
job = store.create_job({"kind": "generate", "gen": 12})

B, S, GEN = 4, 32, 12
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab, jnp.int32)

# --- instance 0: prefill + 5 decode steps, then reclaimed -------------------
dhp = DHP(nbs, "serve-0", store)
logits, caches = model.prefill(params, {"tokens": prompt}, s_max=S + GEN)
tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
generated = [tok]
for i in range(5):
    lg, caches = model.decode(params, caches, tok, jnp.asarray(S + i, jnp.int32))
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated.append(tok)
dhp.publish(job.job_id, STATUS_CKPT,
            {"caches": caches, "tok": tok, "done": 6, "generated": jnp.concatenate(generated, 1)},
            step=6)
print("instance 0 reclaimed after 6/12 tokens; CMI published")

# --- instance 1: resume mid-generation --------------------------------------
dhp2 = DHP(nbs, "serve-1", store)
state, step = dhp2.restart(job.job_id)
caches, tok = state["caches"], jnp.asarray(state["tok"])
generated = [jnp.asarray(state["generated"])]
# gen[j+1] = decode(gen[j], pos=S+j); `done` tokens exist, so continue at j=done-1
for j in range(int(state["done"]) - 1, GEN - 1):
    lg, caches = model.decode(params, caches, tok, jnp.asarray(S + j, jnp.int32))
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    generated.append(tok)
out = np.asarray(jnp.concatenate(generated, axis=1))
dhp2.publish(job.job_id, STATUS_FINISHED, product={"tokens": out})

# --- verify against an uninterrupted run ------------------------------------
logits, caches = model.prefill(params, {"tokens": prompt}, s_max=S + GEN)
tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
ref = [tok]
for i in range(GEN - 1):
    lg, caches = model.decode(params, caches, tok, jnp.asarray(S + i, jnp.int32))
    tok = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)[:, None]
    ref.append(tok)
ref = np.asarray(jnp.concatenate(ref, axis=1))
assert np.array_equal(out, ref), "migrated generation diverged!"
print(f"resumed generation identical to uninterrupted run: {out[0].tolist()}")
print("jobs:", store.svc_list_jobs())
